"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
results/dryrun/*.json — or, with ``--trace FILE``, a latency-decomposition
report from a request-lifecycle trace dump.

    PYTHONPATH=src python -m benchmarks.report [--tag baseline]
    PYTHONPATH=src python -m benchmarks.report --trace serve_trace.jsonl

The trace report decomposes per-request wall time into queue / execute /
score / other (other = end-to-end minus the instrumented spans: routing,
admission, retry re-queues, result plumbing), reports p50/p95/p99 per
component plus the mean composition of the slowest 1% of requests, and
tabulates padding waste per pack class from the engine's batch records.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


# ---- request-lifecycle trace report ----------------------------------------

_PHASES = ("queue", "execute", "score")


def load_trace(path):
    """Split a --trace-dump / /trace JSONL file into request + batch rows."""
    requests, batches = [], []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            row = json.loads(line)
            (requests if row.get("type") == "request" else batches).append(row)
    return requests, batches


def decompose(req):
    """Per-request {phase: seconds} with 'total' and 'other'. Phase spans
    are summed by name, so a retried request's two queue/execute spans
    both count toward its queue/execute share."""
    total = (req["t1"] or req["t0"]) - req["t0"]
    parts = defaultdict(float)
    for s in req["spans"]:
        if s["name"] in _PHASES:
            parts[s["name"]] += s["dur"]
    parts["total"] = total
    parts["other"] = max(0.0, total - sum(parts[p] for p in _PHASES))
    return parts


def _pct(xs, q):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1))))
    return xs[i]


def trace_report(path):
    requests, batches = load_trace(path)
    delivered = [r for r in requests if r.get("outcome") == "delivered"]
    print(f"### Trace report: {path}\n")
    outcomes = defaultdict(int)
    for r in requests:
        outcomes[r.get("outcome") or "active"] += 1
    retries = sum(1 for r in requests
                  for e in r["events"] if e["name"] == "retry")
    print(f"{len(requests)} requests ({dict(sorted(outcomes.items()))}), "
          f"{retries} retries, {len(batches)} batch records\n")
    if delivered:
        decomp = [decompose(r) for r in delivered]
        print("| component | p50 | p95 | p99 | mean share of slowest 1% |")
        print("|---|---|---|---|---|")
        p99_total = _pct([d["total"] for d in decomp], 99)
        tail = [d for d in decomp if d["total"] >= p99_total] or decomp
        for phase in ("total",) + _PHASES + ("other",):
            xs = [d[phase] for d in decomp]
            share = (sum(d[phase] for d in tail)
                     / max(1e-12, sum(d["total"] for d in tail)))
            print(f"| {phase} | {_pct(xs, 50)*1e3:.1f}ms | "
                  f"{_pct(xs, 95)*1e3:.1f}ms | {_pct(xs, 99)*1e3:.1f}ms | "
                  f"{share*100:.1f}% |")
    if batches:
        by_kind = defaultdict(list)
        for b in batches:
            by_kind[b.get("kind", "?")].append(b)
        print("\n| pack class | steps | reqs | computed tok | padded slots | "
              "waste | mean waste/step | max smax/pmax | compiles | "
              "mean wall |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for kind in sorted(by_kind):
            bs = by_kind[kind]
            comp = sum(b["computed_tokens"] for b in bs)
            padded = sum(b["padded_tokens"] for b in bs)
            waste = 1.0 - comp / max(1, padded)
            step_waste = (sum(b.get("padding_waste", 0.0) for b in bs)
                          / len(bs))
            wall = sum(b["wall"] for b in bs) / len(bs)
            smax = max(b.get("smax", 0) for b in bs)
            pmax = max(b.get("pmax", 0) for b in bs)
            print(f"| {kind} | {len(bs)} | "
                  f"{sum(b['n_requests'] for b in bs)} | {comp} | "
                  f"{padded} | {waste:.3f} | {step_waste:.3f} | "
                  f"{smax}/{pmax} | "
                  f"{sum(1 for b in bs if b.get('compiled'))} | "
                  f"{wall*1e3:.1f}ms |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="latency-decomposition report from a trace dump "
                         "(JSONL from --trace-dump or the /trace endpoint)")
    args = ap.parse_args()
    if args.trace:
        trace_report(args.trace)
        return
    from benchmarks.roofline import fraction, load_cells
    cells = load_cells(args.tag)
    by_key = {(c["arch"], c["shape"], c["mesh"]): c for c in cells}
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    print("### Dry-run table (per device, single pod 16x16 unless noted)\n")
    print("| arch | shape | mesh | status | peak GiB | fits | flops/dev | "
          "coll GB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in archs:
        for shape in shapes:
            for mesh in ("pod16x16", "pod2x16x16"):
                c = by_key.get((arch, shape, mesh))
                if c is None:
                    continue
                if c["status"] != "ok":
                    print(f"| {arch} | {shape} | {mesh} | SKIP | — | — | — | "
                          f"— | — |")
                    continue
                m, h = c["memory"], c["hlo"]
                kinds = ",".join(f"{k.split('-')[-1]}"
                                 for k in sorted(h["collective_by_kind"]))
                print(f"| {arch} | {shape} | {mesh} | ok | "
                      f"{fmt_bytes(m['peak_per_device'])} | "
                      f"{'Y' if m['fits'] else 'N'} | "
                      f"{h['flops']:.2e} | "
                      f"{h['collective_bytes']/1e9:.2f} | {kinds} |")

    print("\n### Roofline table (single pod)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "useful | fraction | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    worst = []
    for arch in archs:
        for shape in shapes:
            c = by_key.get((arch, shape, "pod16x16"))
            if c is None or c["status"] != "ok":
                if c is not None:
                    print(f"| {arch} | {shape} | — | — | — | — | — | — | "
                          f"skipped (sub-quadratic rule) |")
                continue
            r = c["roofline"]
            f = fraction(c)
            worst.append((f, arch, shape, r["dominant"]))
            print(f"| {arch} | {shape} | {r['compute_s']*1e3:.2f}ms | "
                  f"{r['memory_s']*1e3:.2f}ms | {r['collective_s']*1e3:.2f}ms "
                  f"| {r['dominant']} | {r['useful_ratio']:.2f} | {f:.3f} | "
                  f"{r['suggestion'][:48]} |")
    worst.sort()
    print("\nworst fractions:",
          ", ".join(f"{a}/{s}={f:.3f}({d})" for f, a, s, d in worst[:5]))


if __name__ == "__main__":
    main()
