"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report [--tag baseline]
"""
from __future__ import annotations

import argparse
from collections import defaultdict

from benchmarks.roofline import fraction, load_cells


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    cells = load_cells(args.tag)
    by_key = {(c["arch"], c["shape"], c["mesh"]): c for c in cells}
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    print("### Dry-run table (per device, single pod 16x16 unless noted)\n")
    print("| arch | shape | mesh | status | peak GiB | fits | flops/dev | "
          "coll GB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in archs:
        for shape in shapes:
            for mesh in ("pod16x16", "pod2x16x16"):
                c = by_key.get((arch, shape, mesh))
                if c is None:
                    continue
                if c["status"] != "ok":
                    print(f"| {arch} | {shape} | {mesh} | SKIP | — | — | — | "
                          f"— | — |")
                    continue
                m, h = c["memory"], c["hlo"]
                kinds = ",".join(f"{k.split('-')[-1]}"
                                 for k in sorted(h["collective_by_kind"]))
                print(f"| {arch} | {shape} | {mesh} | ok | "
                      f"{fmt_bytes(m['peak_per_device'])} | "
                      f"{'Y' if m['fits'] else 'N'} | "
                      f"{h['flops']:.2e} | "
                      f"{h['collective_bytes']/1e9:.2f} | {kinds} |")

    print("\n### Roofline table (single pod)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "useful | fraction | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    worst = []
    for arch in archs:
        for shape in shapes:
            c = by_key.get((arch, shape, "pod16x16"))
            if c is None or c["status"] != "ok":
                if c is not None:
                    print(f"| {arch} | {shape} | — | — | — | — | — | — | "
                          f"skipped (sub-quadratic rule) |")
                continue
            r = c["roofline"]
            f = fraction(c)
            worst.append((f, arch, shape, r["dominant"]))
            print(f"| {arch} | {shape} | {r['compute_s']*1e3:.2f}ms | "
                  f"{r['memory_s']*1e3:.2f}ms | {r['collective_s']*1e3:.2f}ms "
                  f"| {r['dominant']} | {r['useful_ratio']:.2f} | {f:.3f} | "
                  f"{r['suggestion'][:48]} |")
    worst.sort()
    print("\nworst fractions:",
          ", ".join(f"{a}/{s}={f:.3f}({d})" for f, a, s, d in worst[:5]))


if __name__ == "__main__":
    main()
