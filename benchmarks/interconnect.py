"""Fig 8 analog: interconnect-bandwidth sensitivity of TP vs PrefillOnly.

The paper contrasts NVLink vs PCIe for the TP-2 baseline on credit
verification; our analog is full-ICI (50 GB/s/link) vs a DCN-attached slice
(6.25 GB/s). PrefillOnly doesn't parallelize inference, so its throughput is
interconnect-independent — the paper's punchline.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.simulator import Simulator, paper_engines
from repro.data.workloads import credit_verification
from repro.runtime.hw import TPU_V5E, TPU_V5E_SLOW_LINKS

ARCH = "llama3.1-8b"


def run(emit):
    cfg = get_config(ARCH)
    trace = credit_verification(qps=10_000.0, seed=3)   # saturation mode
    rows = {}
    for chip in (TPU_V5E, TPU_V5E_SLOW_LINKS):
        for spec in paper_engines():
            if spec.name not in ("prefillonly", "tensor_parallel",
                                 "pipeline_parallel"):
                continue
            sim = Simulator(cfg, spec, total_chips=2, chip=chip,
                            weight_bytes_per_param=1.0,
                            user_mil=trace.max_len)
            r = sim.run(list(trace.requests), 10_000.0)
            emit(f"interconnect/{chip.name}/{spec.name}", 0.0,
                 f"thr={r.throughput:.3f}rps")
            rows[(chip.name, spec.name)] = r.throughput
    return rows
