"""Kernel micro-benchmarks (CPU host): wall-time of the pure-JAX oracles and
scheduler-path overheads. Pallas kernels run in interpret mode on this host,
so their wall-time is not meaningful — the TPU-side performance story lives
in the dry-run roofline (§Roofline); here we track the host-visible costs
that DO matter at serving time: scheduling decision latency and cache ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.jct import LinearProxyJCT
from repro.core.prefix_cache import PrefixCache, token_chain
from repro.core.scheduler import Request, Scheduler
from repro.models.layers import blocked_attention
from repro.kernels import ref


def run(emit):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    # model-layer attention oracle (jit'd)
    q = jax.random.normal(ks[0], (1, 512, 8, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 512, 4, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 512, 4, 64), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: blocked_attention(q, k, v, q_block=128,
                                                   kv_block=128))
    emit("kernels/blocked_attention_512", time_call(fa, q, k, v),
         "B1 S512 H8 KV4 d64 bf16 (host CPU)")

    x = jax.random.normal(ks[3], (512, 256), jnp.bfloat16)
    wg = jax.random.normal(ks[4], (256, 1024), jnp.bfloat16) * 0.05
    wd = jax.random.normal(ks[5], (1024, 256), jnp.bfloat16) * 0.05
    mlp = jax.jit(lambda x: ref.fused_mlp_ref(x, wg, wg, wd))
    emit("kernels/swiglu_mlp_512x256", time_call(mlp, x),
         "T512 D256 F1024 bf16 (host CPU)")

    # scheduling decision latency at queue depth 256 (Algorithm 1 inner loop)
    cache = PrefixCache(4096, 16)
    rng = np.random.default_rng(0)
    queue = []
    for i in range(256):
        toks = rng.integers(0, 1000, size=rng.integers(500, 15_000)).tolist()
        queue.append(Request(n_input=len(toks), arrival=float(i),
                             chain=token_chain(toks, 16), user_id=f"u{i}"))
    sched = Scheduler("srjf_calibrated", LinearProxyJCT(a=1e-4), lam=0.05)
    import time as _t
    t0 = _t.perf_counter()
    for _ in range(20):
        sched.pick(queue, cache, now=300.0)
    emit("scheduler/pick_depth256", (_t.perf_counter() - t0) / 20 * 1e6,
         "continuous JCT calibration over 256 waiting requests")

    chain = queue[0].chain
    t0 = _t.perf_counter()
    for _ in range(200):
        cache.insert(chain, len(chain) * 16, now=1.0)
    emit("prefix_cache/insert_long", (_t.perf_counter() - t0) / 200 * 1e6,
         f"{len(chain)} blocks")
